"""Model / shape / parallelism configuration.

``ModelConfig`` covers all assigned architecture families: dense decoder
transformers (GQA, qk-norm, QKV-bias, sliding window), MoE, Mamba-1 SSM,
hybrid attention+SSM (Hymba-style), encoder-decoder (Whisper backbone) and
VLM backbones (vision-prefix stub).  ``ShapeSpec`` defines the four assigned
input-shape cells; ``input_kind`` distinguishes training from decode
lowering (decode shapes lower ``serve_step`` with a KV cache, not
``train_step``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None     # SWA width (tokens) or None
    # hybrid archs: full attention at these layer indices, SWA elsewhere
    full_attn_layers: Tuple[int, ...] = ()
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0                 # 0 -> 2 * d_model
    # encoder-decoder (Whisper backbone; conv frontend is a stub)
    encoder_layers: int = 0          # > 0 => enc-dec
    encoder_seq: int = 1500          # audio frame positions after conv stub
    # VLM backbone: first `vision_prefix` positions carry patch embeddings
    vision_prefix: int = 0
    norm_eps: float = 1e-6
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    source: str = ""                 # provenance note ([arXiv/hf ref])

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? SSM state and/or bounded
        sliding-window KV make decode cost independent of context length."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True              # SWA + SSM; few full-attn layers noted
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim_
        n_attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.is_moe:
            n_mlp = self.num_experts * 3 * d * f + d * self.num_experts
        elif f > 0:
            n_mlp = 3 * d * f
        else:
            n_mlp = 0
        n_ssm = 0
        if self.has_ssm:
            di, N, rk = self.d_inner_, self.ssm_state, self.dt_rank
            n_ssm = d * 2 * di + di * self.ssm_conv + di * (rk + 2 * N) \
                + rk * di + di * N + di + di * d
        per_layer = n_attn * (self.family != "ssm") + n_mlp + n_ssm + 2 * d
        n = self.num_layers * per_layer + self.vocab_size * d
        if self.encoder_layers:
            n += self.encoder_layers * (n_attn + n_mlp + 2 * d)
            n += self.num_layers * n_attn    # decoder cross-attention
        if not self.tie_embeddings:
            n += self.vocab_size * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = dataclasses.replace(self, num_experts=0, top_k=0,
                                         d_ff=0)
        return dense_like.param_count() \
            + self.num_layers * (self.top_k * 3 * d * f
                                 + d * self.num_experts)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            d_inner=128 if self.has_ssm else 0,
            ssm_state=min(self.ssm_state, 8) if self.has_ssm else 0,
            sliding_window=16 if self.sliding_window else None,
            full_attn_layers=(0,) if self.full_attn_layers else (),
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=8 if self.encoder_layers else 1500,
            vision_prefix=4 if self.vision_prefix else 0,
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", seq_len=4096, global_batch=256,
                          kind="train"),
    "prefill_32k": ShapeSpec("prefill_32k", seq_len=32_768, global_batch=32,
                             kind="prefill"),
    "decode_32k": ShapeSpec("decode_32k", seq_len=32_768, global_batch=128,
                            kind="decode"),
    "long_500k": ShapeSpec("long_500k", seq_len=524_288, global_batch=1,
                           kind="decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How train/serve steps are partitioned over the mesh."""
    fsdp: bool = True                # shard params/optimizer over "data"
    remat: bool = True               # per-layer activation checkpointing
    scan_layers: bool = True         # stack layers, lax.scan over them
    # sequence parallelism: shard between-layer activations' seq dim over
    # "model" (7x residual-memory reduction at 256 chips; required for the
    # assigned train shapes to fit v5e HBM)
    seq_shard_activations: bool = True
    # serving
    kv_batch_axis: str = "data"
    # gradient accumulation microbatches (1 = none)
    grad_accum: int = 1


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
