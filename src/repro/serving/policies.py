"""Tier-placement policy baselines for the serving scenario family.

Three policies, selectable in the scenario grid the way storage schemes
are (``--policies static,lru,hhzs``):

``static``
    HBM-only with rejection: a sequence is admitted iff its *whole*
    budgeted footprint (prompt + max output tokens) fits in free HBM
    zones, accounting for the unfilled growth of already-admitted
    sequences.  Never demotes, never migrates — the "provision for peak
    or shed" strawman a tiered design is measured against.

``lru``
    Two-tier with plain LRU demotion and no hints: every prefill lands in
    HBM regardless of demand, the demotion victim is chosen purely by
    recency, and there is no prefix cache.  This is the classic
    hint-blind paging baseline (≙ the conventional-zoned-storage baseline
    of the paper's evaluation).

``hhzs``
    The full hint-driven manager (`HHZSKVManager`): §3.3 write-guided
    placement, §3.4 capacity/popularity migration with level-aware victim
    choice, §3.5 eviction-driven prefix caching.
"""
from __future__ import annotations

from typing import Dict

from .paged_kv import PagedPool
from .tiering import HHZSKVManager, SeqKV

POLICIES = ("static", "lru", "hhzs")


class LRUKVManager(HHZSKVManager):
    """Hint-blind baseline: HBM-first placement, recency-only eviction,
    no prefix cache."""

    def __init__(self, hbm: PagedPool, host: PagedPool,
                 migration_zone_budget_per_step: int = 1):
        super().__init__(
            hbm, host, cache_zones=0,
            migration_zone_budget_per_step=migration_zone_budget_per_step)

    def on_prefill(self, sid: int, tokens: int) -> SeqKV:
        # no write-guided placement: always start in HBM and let demand
        # pressure evict whoever is least recently used
        seq = SeqKV(sid=sid, last_active_step=self.step, tier="hbm")
        self.seqs[sid] = seq
        self.stats["hbm_placements"] += 1
        return seq

    def _victim_key(self, s: SeqKV):
        return (self.step - s.last_active_step, s.sid)

    def tick(self, active_sids) -> None:
        # hint-blind paging: an active host-resident sequence is promoted
        # by evicting whoever is least recently used — even another
        # sequence of the current batch (the promote/demote ping-pong the
        # hinted manager's cold-only rule avoids)
        self.step += 1
        for sid in active_sids:
            if sid in self.seqs:
                self.seqs[sid].last_active_step = self.step
        budget = self.migration_budget
        for sid in active_sids:
            seq = self.seqs.get(sid)
            if seq is None or seq.tier != "host" or budget <= 0:
                continue
            if self.hbm.num_free() >= len(seq.zones):
                budget -= self._promote(seq)
            elif self._demote_one(exclude=sid):
                budget -= self._promote(seq)


class StaticHBMManager(HHZSKVManager):
    """HBM-only with admission rejection; no host tier, no migration."""

    def __init__(self, hbm: PagedPool, host: PagedPool):
        super().__init__(hbm, host, cache_zones=0,
                         migration_zone_budget_per_step=0)
        self._commit: Dict[int, int] = {}   # sid -> budgeted total tokens

    def _outstanding(self) -> int:
        """HBM zones already promised to admitted sequences but not yet
        allocated (their future decode growth)."""
        out = 0
        for sid, total in self._commit.items():
            seq = self.seqs.get(sid)
            held = len(seq.zones) if seq is not None else 0
            out += max(0, self._zones_for(total) - held)
        return out

    def admit(self, sid: int, total_tokens: int) -> bool:
        if self._zones_for(total_tokens) > \
                self.hbm.num_free() - self._outstanding():
            return False
        self._commit[sid] = total_tokens
        return True

    def on_prefill(self, sid: int, tokens: int) -> SeqKV:
        seq = SeqKV(sid=sid, last_active_step=self.step, tier="hbm")
        self.seqs[sid] = seq
        self.stats["hbm_placements"] += 1
        return seq

    def writable_zone(self, seq: SeqKV):
        if seq.zones and seq.zones[-1].remaining(self.hbm.page_size) > 0:
            return seq.zones[-1]
        z = self.hbm.alloc_zone(seq.sid)
        if z is None:
            raise RuntimeError(
                "static policy: HBM pool exhausted — admission reservation "
                "accounting is broken")
        seq.zones.append(z)
        return z

    def tick(self, active_sids) -> None:
        self.step += 1
        for sid in active_sids:
            if sid in self.seqs:
                self.seqs[sid].last_active_step = self.step

    def release(self, sid: int) -> None:
        super().release(sid)
        self._commit.pop(sid, None)


def make_manager(policy: str, hbm: PagedPool, host: PagedPool, *,
                 cache_zones: int = 2,
                 migration_zone_budget_per_step: int = 1) -> HHZSKVManager:
    if policy == "static":
        return StaticHBMManager(hbm, host)
    if policy == "lru":
        return LRUKVManager(
            hbm, host,
            migration_zone_budget_per_step=migration_zone_budget_per_step)
    if policy == "hhzs":
        return HHZSKVManager(
            hbm, host, cache_zones=cache_zones,
            migration_zone_budget_per_step=migration_zone_budget_per_step)
    raise ValueError(f"unknown serving policy {policy!r} "
                     f"(known: {', '.join(POLICIES)})")
