"""Continuous-batching serving engine over HHZS-tiered paged KV.

A compact but real engine: request queue -> admission -> prefill ->
interleaved decode with continuous batching.  The KV cache is paged and
two-tier (HBM/host) under the HHZS-style manager; decode attention runs
through the paged-attention kernel (interpret mode off-TPU) or its jnp
reference.  Preemption on HBM pressure *is* capacity migration; resumption
*is* popularity migration; prefix caching covers resumed sequences' first
pages — the paper's three techniques, end to end, on the serving path.

Deliberately single-host/single-stream (the multi-chip serving path is the
dry-run's serve_step); used by examples/serve_paged.py and the tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

try:                                    # the real engine needs jax; the
    import jax                          # sim serving path (workloads/
    import jax.numpy as jnp             # serving.py) does not
    from ..models import layers as L
    from ..models import model as M
except ImportError:                     # pragma: no cover - no-jax CI leg
    jax = None

from ..config import ModelConfig
from .paged_kv import PagedPool
from .tiering import HHZSKVManager


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # int32 tokens
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    state: str = "queued"            # queued | running | paused | done
    enqueued_step: int = 0

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.out_tokens)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 hbm_zones: int = 8, host_zones: int = 64,
                 pages_per_zone: int = 4, page_size: int = 16,
                 max_batch: int = 4, cache_zones: int = 1,
                 use_kernel: bool = False, seed: int = 0):
        if jax is None:
            raise RuntimeError("ServingEngine requires jax; the jax-free "
                               "serving path is repro.workloads.serving")
        assert cfg.family in ("dense",), "engine demo supports dense archs"
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        mk = lambda name, zones, host: PagedPool(
            name, cfg.num_layers, zones, pages_per_zone, page_size,
            cfg.num_kv_heads, cfg.head_dim_, host=host)
        self.hbm = mk("hbm", hbm_zones, host=False)
        self.host = mk("host", host_zones, host=True)
        self.mgr = HHZSKVManager(self.hbm, self.host,
                                 cache_zones=cache_zones)
        self.max_batch = max_batch
        self.use_kernel = use_kernel
        self.queue: List[Request] = []
        self.running: List[Request] = []
        self.done: List[Request] = []
        self.steps = 0
        self.tokens_out = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.enqueued_step = self.steps
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _forward_tokens(self, req: Request, tokens: np.ndarray) -> int:
        """Run tokens through the model, appending KV to the paged store.
        Returns the argmax next token after the last position."""
        cfg, p = self.cfg, self.params
        seq = self.mgr.seqs[req.rid]
        x = p["embed"][jnp.asarray(tokens)[None, :]]     # [1, T, d]
        positions = (jnp.arange(len(tokens)) + seq.length)[None, :]
        kv_cached = []                                    # per layer (k, v)
        for li in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[li], p["layers"])
            h = L.rms_norm(x, layer["attn_norm"], cfg.norm_eps)
            q, k, v = L._project_qkv(layer["attn"], cfg, h, h)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            kv_cached.append((k[0], v[0]))
            # attention over (resident KV) + (new tokens)
            pk, pv = self._gather_kv(req, li)             # [S_prev, KV, D]
            full_k = jnp.concatenate([pk, k[0]], axis=0)[None]
            full_v = jnp.concatenate([pv, v[0]], axis=0)[None]
            out = L.sdpa(q, full_k, full_v,
                         cfg.num_heads // cfg.num_kv_heads, causal=True,
                         q_offset=int(seq.length))
            x = x + out @ layer["attn"]["wo"]
            h = L.rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
            x = x + L.mlp(layer["mlp"], cfg, h)
        # append KV token by token (zone write pointers advance append-only)
        for t in range(len(tokens)):
            zone = self.mgr.writable_zone(seq)
            pool = self.mgr.pool_of(seq)
            lk = jnp.stack([kv[0][t] for kv in kv_cached])   # [L, KV, D]
            lv = jnp.stack([kv[1][t] for kv in kv_cached])
            pool.write_token(zone, lk, lv)
            seq.length += 1
        x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
        logits = x[0, -1] @ M.lm_head(cfg, p)
        return int(jnp.argmax(logits))

    def _gather_kv(self, req: Request, layer: int):
        """All resident KV of a sequence for one layer: [S, KV, D]."""
        seq = self.mgr.seqs[req.rid]
        pool = self.mgr.pool_of(seq)
        ks, vs = [], []
        remaining = seq.length
        for z in seq.zones:
            for pg in z.pages:
                take = min(remaining, self.page_size)
                if take <= 0:
                    break
                ks.append(jnp.asarray(pool.k[layer, pg, :take]))
                vs.append(jnp.asarray(pool.v[layer, pg, :take]))
                remaining -= take
        if not ks:
            d = (0, self.cfg.num_kv_heads, self.cfg.head_dim_)
            return jnp.zeros(d, jnp.float32), jnp.zeros(d, jnp.float32)
        return jnp.concatenate(ks), jnp.concatenate(vs)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit, prefill one, decode all running."""
        self.steps += 1
        # admission
        while self.queue and len(self.running) < self.max_batch:
            req = self.queue.pop(0)
            self.mgr.on_prefill(req.rid, len(req.prompt))
            nxt = self._forward_tokens(req, req.prompt)
            req.out_tokens.append(nxt)
            req.state = "running"
            self.running.append(req)
            self.tokens_out += 1
        # migration tick with the active set
        self.mgr.tick([r.rid for r in self.running])
        # decode one token for every running sequence
        for req in list(self.running):
            nxt = self._forward_tokens(
                req, np.asarray([req.out_tokens[-1]], np.int32))
            req.out_tokens.append(nxt)
            self.tokens_out += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.state = "done"
                self.running.remove(req)
                self.done.append(req)
                self.mgr.release(req.rid)

    def run(self, max_steps: int = 100) -> Dict:
        while (self.queue or self.running) and self.steps < max_steps:
            self.step()
        st = dict(self.mgr.stats)
        st.update(steps=self.steps, tokens_out=self.tokens_out,
                  done=len(self.done),
                  hbm_free_zones=self.hbm.num_free(),
                  host_free_zones=self.host.num_free())
        return st
