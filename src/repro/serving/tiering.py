"""HHZS-hinted tier manager for paged KV caches (HBM <-> host).

Reuses the paper's three techniques on the KV-cache placement problem,
driven by the same hint vocabulary (repro.core.hints):

  write-guided placement   new KV zones (prefill ≙ flush, growth past a
      length bucket ≙ compaction into the next level) go to HBM while the
      *demand* of active sequences fits — demand is computed from admitted
      requests exactly as §3.3 computes per-level storage demands from
      flushing/compaction hints;
  workload-aware migration rate-limited background promotion/demotion:
      paused or preempted sequences (lowest priority: deeper length bucket,
      colder access) demote to host; resumed sequences promote back —
      §3.4's capacity/popularity migration with the HDD read-rate trigger
      replaced by the decode scheduler's active set;
  hinted caching           a reserved HBM zone pool caches the *prefix*
      (attention-sink) pages of host-resident sequences — the blocks every
      future decode step of that sequence will touch first (the cache hint
      fires when a sequence demotes, i.e. when its pages are evicted from
      the fast tier, mirroring §3.5's eviction-driven admission).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.hints import CacheHint, CompactionOutputHint, FlushHint
from .paged_kv import KVZone, PagedPool


@dataclass
class SeqKV:
    sid: int
    length: int = 0
    tier: str = "hbm"                     # "hbm" | "host"
    zones: List[KVZone] = field(default_factory=list)
    last_active_step: int = 0
    prefix_cached: bool = False

    def level(self, base: int = 512) -> int:
        """Length bucket ≙ LSM level (exponentially growing)."""
        lvl = 0
        n = max(self.length, 1)
        while n > base:
            n //= 4
            lvl += 1
        return lvl

    def priority_key(self, step: int) -> Tuple[int, int]:
        """Smaller = higher priority: recently active first, then shallower
        level (short sequences are cheap to keep hot)."""
        return (step - self.last_active_step, self.level())


class HHZSKVManager:
    def __init__(self, hbm: PagedPool, host: PagedPool,
                 cache_zones: int = 2,
                 migration_zone_budget_per_step: int = 1):
        self.hbm = hbm
        self.host = host
        self.seqs: Dict[int, SeqKV] = {}
        self.step = 0
        # reserved HBM zones for prefix caching (≙ WAL/cache zones)
        self.cache_pool: List[KVZone] = []
        for _ in range(cache_zones):
            z = hbm.alloc_zone(owner=-1)
            if z is not None:
                self.cache_pool.append(z)
        self.prefix_cache: Dict[int, KVZone] = {}   # sid -> cache zone
        self._cache_fifo: List[int] = []
        self.migration_budget = migration_zone_budget_per_step
        self.stats = {"demotions": 0, "promotions": 0, "cache_admits": 0,
                      "cache_hits": 0, "bytes_migrated": 0,
                      "hbm_placements": 0, "host_placements": 0,
                      "demote_pages": 0, "promote_pages": 0,
                      "preempt_stalls": 0}

    # ------------------------------------------------------------------
    # hints
    # ------------------------------------------------------------------
    def admit(self, sid: int, total_tokens: int) -> bool:
        """Capacity admission hook: may this sequence (prompt + budgeted
        output, ``total_tokens``) enter at all?  The tiered policies always
        admit — host capacity backs the overflow; the static HBM-only
        baseline overrides this with a reject-on-full check."""
        return True

    def on_prefill(self, sid: int, tokens: int) -> SeqKV:
        """Flush hint: a new KV segment appears.

        Write-guided placement (§3.3): the incoming sequence is *hot* (it
        decodes immediately), so the fast tier is cleared for it by
        demoting cold residents — never active ones — until its demand
        fits.  Only when no cold victim remains does the prefill land on
        the slow tier."""
        seq = SeqKV(sid=sid, last_active_step=self.step)
        self.seqs[sid] = seq
        need = self._zones_for(tokens)
        while self.hbm.num_free() < need + self._active_demand() \
                and self._demote_one(exclude=sid, cold_only=True):
            pass
        if self.hbm.num_free() >= need + self._active_demand():
            seq.tier = "hbm"
            self.stats["hbm_placements"] += 1
        else:
            seq.tier = "host"
            self.stats["host_placements"] += 1
        return seq

    def on_growth(self, seq: SeqKV) -> None:
        """Compaction hint analogue: sequence crossed a level boundary."""
        # placement re-evaluated on the next zone allocation

    def _zones_for(self, tokens: int) -> int:
        zsz = self.hbm.page_size * self.hbm.pages_per_zone
        return -(-max(tokens, 1) // zsz)

    def _active_demand(self) -> int:
        """Zones the currently-active set will need soon (≙ §3.3 demands)."""
        demand = 0
        for s in self.seqs.values():
            if s.tier == "hbm" and self.step - s.last_active_step <= 1:
                if s.zones and s.zones[-1].remaining(self.hbm.page_size) < 8:
                    demand += 1
        return demand

    # ------------------------------------------------------------------
    # allocation on the write path
    # ------------------------------------------------------------------
    def pool_of(self, seq: SeqKV) -> PagedPool:
        return self.hbm if seq.tier == "hbm" else self.host

    def writable_zone(self, seq: SeqKV) -> KVZone:
        pool = self.pool_of(seq)
        if seq.zones and seq.zones[-1].remaining(pool.page_size) > 0:
            return seq.zones[-1]
        z = pool.alloc_zone(seq.sid)
        if z is None and seq.tier == "hbm":
            # capacity migration: demote the lowest-priority HBM sequence
            if not self._demote_one(exclude=seq.sid):
                self._seq_to_host(seq)
                return self.writable_zone(seq)
            z = pool.alloc_zone(seq.sid)
        if z is None:
            z = self.host.alloc_zone(seq.sid)
            if z is None:
                raise RuntimeError("host KV pool exhausted")
            if seq.tier == "hbm":
                self._seq_to_host(seq)
        seq.zones.append(z)
        return z

    # ------------------------------------------------------------------
    # migration (≙ §3.4, rate-limited per decode step)
    # ------------------------------------------------------------------
    def tick(self, active_sids: List[int]) -> None:
        """Called once per decode step with the active sequence set."""
        self.step += 1
        for sid in active_sids:
            if sid in self.seqs:
                self.seqs[sid].last_active_step = self.step
        budget = self.migration_budget
        # popularity migration: promote active host-resident sequences —
        # into free slack, or by displacing *cold* residents only.  The
        # hint keeps a promotion from evicting another active sequence
        # (the ping-pong a hint-blind pager pays; cf. LRUKVManager.tick)
        for sid in active_sids:
            seq = self.seqs.get(sid)
            if seq is None or seq.tier != "host" or budget <= 0:
                continue
            while self.hbm.num_free() < len(seq.zones) \
                    and self._demote_one(exclude=sid, cold_only=True):
                pass
            if self.hbm.num_free() >= len(seq.zones):
                budget -= self._promote(seq)

    def _victim_key(self, s: SeqKV):
        """Demotion victim ordering (max wins).  The hinted policy uses the
        paper's hint vocabulary — coldest first, then deepest length bucket
        (short sequences are cheap to keep hot); the LRU baseline overrides
        this with pure recency."""
        return s.priority_key(self.step)

    def _demote_one(self, exclude: int, cold_only: bool = False) -> bool:
        cands = [s for s in self.seqs.values()
                 if s.tier == "hbm" and s.sid != exclude and s.zones
                 and not (cold_only
                          and s.last_active_step >= self.step)]
        if not cands:
            return False
        victim = max(cands, key=self._victim_key)
        if victim.last_active_step >= self.step:
            # evicting a sequence that decoded this very step: the next
            # decode of that sequence stalls on host-resident KV
            self.stats["preempt_stalls"] += 1
        self._seq_to_host(victim)
        self.stats["demotions"] += 1
        return True

    def _seq_to_host(self, seq: SeqKV) -> None:
        # hinted caching first (≙ §3.5 eviction-driven admission): the
        # prefix must be copied while its HBM zones still hold valid data —
        # admitting after the reset below would cache an empty zone and
        # read from freed pages
        self._cache_admit(seq)
        new_zones = []
        for z in seq.zones:
            dz = self.host.alloc_zone(seq.sid)
            if dz is None:
                raise RuntimeError("host KV pool exhausted")
            self.stats["bytes_migrated"] += \
                self.host.copy_zone_from(self.hbm, z, dz)
            self.stats["demote_pages"] += len(z.pages)
            self.hbm.reset_zone(z)
            new_zones.append(dz)
        seq.zones = new_zones
        seq.tier = "host"

    def _promote(self, seq: SeqKV) -> int:
        # all-or-nothing: reserve every destination zone before touching a
        # single source zone, so an abort cannot strand a live sequence
        # pointing at freed host zones (partial-promotion data loss)
        new_zones = []
        for _ in seq.zones:
            dz = self.hbm.alloc_zone(seq.sid)
            if dz is None:
                for nz in new_zones:
                    self.hbm.reset_zone(nz)
                return 0
            new_zones.append(dz)
        for z, dz in zip(seq.zones, new_zones):
            self.stats["bytes_migrated"] += \
                self.hbm.copy_zone_from(self.host, z, dz)
            self.stats["promote_pages"] += len(z.pages)
            self.host.reset_zone(z)
        seq.zones = new_zones
        seq.tier = "hbm"
        self.stats["promotions"] += 1
        self._cache_drop(seq.sid)   # resident again: cached copy redundant
        return max(len(new_zones), 1)

    # ------------------------------------------------------------------
    # prefix caching (≙ §3.5)
    # ------------------------------------------------------------------
    def _cache_admit(self, seq: SeqKV) -> None:
        if not self.cache_pool or seq.sid in self.prefix_cache \
                or not seq.zones:
            return
        if len(self.prefix_cache) >= len(self.cache_pool):
            # FIFO zone eviction: the new entry takes over the *evicted*
            # entry's zone — indexing by occupancy here would overwrite a
            # zone another cached sequence still maps (cache collision)
            old = self._cache_fifo.pop(0)
            zone = self.prefix_cache.pop(old)
            old_seq = self.seqs.get(old)
            if old_seq is not None:
                old_seq.prefix_cached = False
        else:
            used = {z.zid for z in self.prefix_cache.values()}
            zone = next(z for z in self.cache_pool if z.zid not in used)
        self.hbm.copy_zone_from(self.hbm, seq.zones[0], zone)
        self.prefix_cache[seq.sid] = zone
        self._cache_fifo.append(seq.sid)
        seq.prefix_cached = True
        self.stats["cache_admits"] += 1

    def _cache_drop(self, sid: int) -> None:
        if sid in self.prefix_cache:
            self.prefix_cache.pop(sid)
            if sid in self._cache_fifo:
                self._cache_fifo.remove(sid)
            seq = self.seqs.get(sid)
            if seq is not None:
                seq.prefix_cached = False

    def cache_lookup(self, sid: int) -> Optional[KVZone]:
        z = self.prefix_cache.get(sid)
        if z is not None:
            self.stats["cache_hits"] += 1
        return z

    def residency(self, seq: SeqKV) -> Tuple[int, int]:
        """(hbm_tokens, host_tokens) a full attention read of this sequence
        touches right now.  For a host-resident sequence the cached prefix
        zone (if any) serves its span at HBM speed — the §3.5 payoff the
        serving cost model charges for."""
        if seq.tier == "hbm":
            return seq.length, 0
        cz = self.cache_lookup(seq.sid)
        cached = min(cz.write_ptr, seq.length) if cz is not None else 0
        return cached, seq.length - cached

    # ------------------------------------------------------------------
    def release(self, sid: int) -> None:
        """Sequence finished: reset all its zones (zone-granular reclaim)."""
        seq = self.seqs.pop(sid, None)
        if seq is None:
            return
        pool = self.pool_of(seq)
        for z in seq.zones:
            pool.reset_zone(z)
        self._cache_drop(sid)
