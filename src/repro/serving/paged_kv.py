"""Paged KV storage with zone semantics, two-tier (HBM / host).

The HHZS -> TPU mapping (DESIGN.md §Hardware-adaptation):

  ZNS SSD            -> HBM page pool (fast, scarce)
  HM-SMR HDD         -> host-memory page pool (slow, plentiful)
  zone               -> fixed group of KV pages, allocated append-only via
                        a write pointer and reset *as a unit* when the
                        owning sequence retires (no per-page GC — the same
                        no-translation-layer property zoned storage gives)
  SST                -> one sequence's KV segment (a list of zones)
  LSM level          -> sequence length bucket (exponentially growing)

Pools hold stacked per-layer pages [L, P, page_size, KV, D].  The host tier
is numpy (pageable host RAM); promotion/demotion copies zones between
tiers, modelling the d2h/h2d DMA a real TPU serving stack issues.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclass
class KVZone:
    zid: int
    pages: List[int]               # page indices inside the pool
    write_ptr: int = 0             # tokens written into this zone
    owner: Optional[int] = None    # sequence id

    def remaining(self, page_size: int) -> int:
        return len(self.pages) * page_size - self.write_ptr


class PagedPool:
    """One tier's KV pages grouped into zones."""

    def __init__(self, name: str, num_layers: int, num_zones: int,
                 pages_per_zone: int, page_size: int, kv_heads: int,
                 head_dim: int, host: bool):
        self.name = name
        self.page_size = page_size
        self.pages_per_zone = pages_per_zone
        self.num_pages = num_zones * pages_per_zone
        shape = (num_layers, self.num_pages, page_size, kv_heads, head_dim)
        if host:
            self.k = np.zeros(shape, np.float32)
            self.v = np.zeros(shape, np.float32)
        else:
            self.k = jnp.zeros(shape, jnp.float32)
            self.v = jnp.zeros(shape, jnp.float32)
        self.host = host
        self.zones = [
            KVZone(zid=i, pages=list(range(i * pages_per_zone,
                                           (i + 1) * pages_per_zone)))
            for i in range(num_zones)]
        self._free = list(range(num_zones))
        # traffic accounting (bytes) for the serving report
        self.bytes_written = 0
        self.bytes_read = 0

    def num_free(self) -> int:
        return len(self._free)

    def alloc_zone(self, owner: int) -> Optional[KVZone]:
        if not self._free:
            return None
        z = self.zones[self._free.pop(0)]
        z.owner = owner
        z.write_ptr = 0
        return z

    def reset_zone(self, zone: KVZone) -> None:
        """Zone reset: write pointer to start, space reclaimed at once."""
        zone.owner = None
        zone.write_ptr = 0
        self._free.append(zone.zid)

    # ------------------------------------------------------------------
    def write_token(self, zone: KVZone, layer_k, layer_v) -> int:
        """Append one token's [L, KV, D] K/V at the zone write pointer.
        Returns the global (page, slot) encoded position."""
        assert zone.remaining(self.page_size) > 0
        idx = zone.write_ptr
        page = zone.pages[idx // self.page_size]
        slot = idx % self.page_size
        if self.host:
            self.k[:, page, slot] = np.asarray(layer_k)
            self.v[:, page, slot] = np.asarray(layer_v)
        else:
            self.k = self.k.at[:, page, slot].set(layer_k)
            self.v = self.v.at[:, page, slot].set(layer_v)
        zone.write_ptr += 1
        self.bytes_written += layer_k.size * 4 * 2
        return page * self.page_size + slot

    def copy_zone_from(self, other: "PagedPool", src: KVZone,
                       dst: KVZone) -> int:
        """Migrate a zone's pages between tiers. Returns bytes moved."""
        moved = 0
        for i, (sp, dp) in enumerate(zip(src.pages, dst.pages)):
            if self.host:
                self.k[:, dp] = np.asarray(other.k[:, sp])
                self.v[:, dp] = np.asarray(other.v[:, sp])
            else:
                self.k = self.k.at[:, dp].set(jnp.asarray(other.k[:, sp]))
                self.v = self.v.at[:, dp].set(jnp.asarray(other.v[:, sp]))
            moved += other.k[:, sp].size * 4 * 2
        dst.write_ptr = src.write_ptr
        other.bytes_read += moved
        self.bytes_written += moved
        return moved
