"""Paged KV storage with zone semantics, two-tier (HBM / host).

The HHZS -> TPU mapping (DESIGN.md §Hardware-adaptation):

  ZNS SSD            -> HBM page pool (fast, scarce)
  HM-SMR HDD         -> host-memory page pool (slow, plentiful)
  zone               -> fixed group of KV pages, allocated append-only via
                        a write pointer and reset *as a unit* when the
                        owning sequence retires (no per-page GC — the same
                        no-translation-layer property zoned storage gives)
  SST                -> one sequence's KV segment (a list of zones)
  LSM level          -> sequence length bucket (exponentially growing)

Pools hold stacked per-layer pages [L, P, page_size, KV, D].  The host tier
is numpy (pageable host RAM); promotion/demotion copies zones between
tiers, modelling the d2h/h2d DMA a real TPU serving stack issues.

Two knobs added for the scenario pipeline:

* jax is optional — without it the device tier falls back to numpy (the
  simulation is bit-identical; only the array backend changes), so the
  serving correctness suite runs honestly on the no-jax CI leg;
* ``materialize=False`` builds an accounting-only pool: zones, write
  pointers, byte counters and conservation invariants all behave exactly
  as with real arrays, but no tensor data is stored or copied — what the
  open-loop serving grid uses (thousands of sequences per cell).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

try:                                    # optional: no-jax CI leg / grid runs
    import jax.numpy as jnp
except ImportError:                     # pragma: no cover - exercised in CI
    jnp = None


@dataclass
class KVZone:
    zid: int
    pages: List[int]               # page indices inside the pool
    write_ptr: int = 0             # tokens written into this zone
    owner: Optional[int] = None    # sequence id

    def remaining(self, page_size: int) -> int:
        return len(self.pages) * page_size - self.write_ptr


class PagedPool:
    """One tier's KV pages grouped into zones."""

    def __init__(self, name: str, num_layers: int, num_zones: int,
                 pages_per_zone: int, page_size: int, kv_heads: int,
                 head_dim: int, host: bool, materialize: bool = True):
        self.name = name
        self.page_size = page_size
        self.pages_per_zone = pages_per_zone
        self.num_pages = num_zones * pages_per_zone
        # bytes of one token's K+V across all layers (float32 K and V)
        self.token_bytes = num_layers * kv_heads * head_dim * 4 * 2
        self.materialize = materialize
        shape = (num_layers, self.num_pages, page_size, kv_heads, head_dim)
        if not materialize:
            self.k = self.v = None
        elif host or jnp is None:
            self.k = np.zeros(shape, np.float32)
            self.v = np.zeros(shape, np.float32)
        else:
            self.k = jnp.zeros(shape, jnp.float32)
            self.v = jnp.zeros(shape, jnp.float32)
        self.host = host
        self.zones = [
            KVZone(zid=i, pages=list(range(i * pages_per_zone,
                                           (i + 1) * pages_per_zone)))
            for i in range(num_zones)]
        self._free = list(range(num_zones))
        # traffic accounting (bytes) for the serving report
        self.bytes_written = 0
        self.bytes_read = 0

    def num_free(self) -> int:
        return len(self._free)

    def alloc_zone(self, owner: int) -> Optional[KVZone]:
        if not self._free:
            return None
        z = self.zones[self._free.pop(0)]
        if z.owner is not None:
            raise RuntimeError(
                f"{self.name}: free-list zone {z.zid} still owned by "
                f"{z.owner} — zone accounting corrupted")
        z.owner = owner
        z.write_ptr = 0
        return z

    def reset_zone(self, zone: KVZone) -> None:
        """Zone reset: write pointer to start, space reclaimed at once.

        Double-resetting a zone would put it on the free list twice and
        hand it to two owners later — raise instead (the symptom of a
        tier-manager bookkeeping bug, not a recoverable condition).
        """
        if zone.owner is None:
            raise RuntimeError(
                f"{self.name}: zone {zone.zid} reset twice (already free)")
        zone.owner = None
        zone.write_ptr = 0
        self._free.append(zone.zid)

    # ------------------------------------------------------------------
    def write_token(self, zone: KVZone, layer_k=None, layer_v=None) -> int:
        """Append one token's [L, KV, D] K/V at the zone write pointer.
        Returns the global (page, slot) encoded position.  On an
        accounting-only pool (``materialize=False``) the tensors may be
        omitted; only pointers and byte counters advance."""
        assert zone.remaining(self.page_size) > 0
        idx = zone.write_ptr
        page = zone.pages[idx // self.page_size]
        slot = idx % self.page_size
        if self.materialize:
            if layer_k is None or layer_v is None:
                raise ValueError("materialized pool needs K/V tensors")
            if isinstance(self.k, np.ndarray):
                self.k[:, page, slot] = np.asarray(layer_k)
                self.v[:, page, slot] = np.asarray(layer_v)
            else:
                self.k = self.k.at[:, page, slot].set(layer_k)
                self.v = self.v.at[:, page, slot].set(layer_v)
        zone.write_ptr += 1
        self.bytes_written += self.token_bytes
        return page * self.page_size + slot

    def read_token(self, zone: KVZone, idx: int):
        """Read back one written token's (K, V) ([L, KV, D] each) — the
        materialized-pool verification path of the serving differential."""
        if not self.materialize:
            raise ValueError("accounting-only pool holds no data")
        if not 0 <= idx < zone.write_ptr:
            raise IndexError(f"token {idx} not written (ptr={zone.write_ptr})")
        page = zone.pages[idx // self.page_size]
        slot = idx % self.page_size
        return (np.asarray(self.k[:, page, slot]),
                np.asarray(self.v[:, page, slot]))

    def copy_zone_from(self, other: "PagedPool", src: KVZone,
                       dst: KVZone) -> int:
        """Migrate a zone's written tokens between tiers. Returns bytes
        moved.  Only pages covered by the source write pointer move (a
        partially-filled zone does not pay for — or corrupt — its empty
        tail), and the destination must have room for the written span."""
        if self.page_size != other.page_size:
            raise ValueError(
                f"page-size mismatch: {self.name}={self.page_size} "
                f"vs {other.name}={other.page_size}")
        if src.write_ptr > len(dst.pages) * self.page_size:
            raise ValueError(
                f"zone copy overflow: {src.write_ptr} tokens into "
                f"{len(dst.pages)}x{self.page_size}-token zone")
        n_pages = -(-src.write_ptr // self.page_size)   # ceil
        moved = 0
        for i in range(n_pages):
            sp, dp = src.pages[i], dst.pages[i]
            if self.materialize and other.materialize:
                if isinstance(self.k, np.ndarray):
                    self.k[:, dp] = np.asarray(other.k[:, sp])
                    self.v[:, dp] = np.asarray(other.v[:, sp])
                else:
                    self.k = self.k.at[:, dp].set(jnp.asarray(other.k[:, sp]))
                    self.v = self.v.at[:, dp].set(jnp.asarray(other.v[:, sp]))
            tokens = min(self.page_size, src.write_ptr - i * self.page_size)
            moved += tokens * other.token_bytes
        dst.write_ptr = src.write_ptr
        other.bytes_read += moved
        self.bytes_written += moved
        return moved
