from .paged_kv import PagedPool, KVZone
from .tiering import HHZSKVManager, SeqKV
from .policies import (POLICIES, LRUKVManager, StaticHBMManager,
                       make_manager)
# the real model-driven engine needs jax; everything above (pools, tier
# managers, policies, the sim serving path) runs on numpy alone
from .engine import ServingEngine, Request

__all__ = ["PagedPool", "KVZone", "HHZSKVManager", "SeqKV",
           "POLICIES", "LRUKVManager", "StaticHBMManager", "make_manager",
           "ServingEngine", "Request"]
