from .paged_kv import PagedPool, KVZone
from .tiering import HHZSKVManager, SeqKV
from .engine import ServingEngine, Request

__all__ = ["PagedPool", "KVZone", "HHZSKVManager", "SeqKV",
           "ServingEngine", "Request"]
